"""Differential sweep: out-of-core ``run_stream`` == in-memory ``api.run``.

PR 4 proved the carry contract resumes bit-exactly when *the caller*
splits an in-memory trace; this module extends that guarantee to the
ingestion path: a trace that arrives as loader chunks from disk (through
the catalog remapper) or as synthesizer chunks must replay **bit-exact**
identically to a one-shot in-memory run — hits, fractional reward, and
every leaf of the final carry — for every registered trace-driven
PolicyDef kind, whatever the incoming chunking.
"""

import os
import tempfile

import numpy as np
import pytest

import jax

from repro.cachesim import api
from repro.cachesim.results import StreamResult
from repro.cachesim.tracelab import (
    CatalogRemap,
    fit_profile,
    open_trace,
    run_stream,
    synthesize,
    synthesize_chunks,
    write_trace,
)
from repro.cachesim.traces import zipf
from repro.core.regret import best_static_hits

#: every kind the one run/sweep engine serves on request-id traces
STREAM_KINDS = tuple(
    k for k in api.policy_def_kinds() if api.policy_def(k).trace_driven
)

N, C, T = 311, 23, 6400
WINDOW = 16


def _kind_kwargs(kind):
    """eta is only a fractional-policy parameter; ogb_sized additionally
    needs per-item sizes (slabs here, so its size classes are exact)."""
    kw = {"eta": 0.03} if api.policy_def(kind).fractional else {}
    if kind == "ogb_sized":
        kw["sizes"] = np.asarray([1.0, 2.0, 4.0, 8.0])[np.arange(N) % 4]
    return kw


def test_stream_kinds_cover_the_registry():
    # the sweep below must cover every replayable kind (ogb_grad streams
    # dense gradients, not request ids, and is rightly excluded)
    assert set(STREAM_KINDS) == {
        "ogb", "ogb_tree", "omd", "lru", "fifo", "lfu", "ftpl",
        "gds", "ogb_sized",
    }


@pytest.mark.parametrize("kind", STREAM_KINDS)
def test_run_stream_matches_in_memory_run(kind):
    """Ragged ingestion chunks + segment re-batching == one-shot api.run."""
    trace = zipf(N, T, alpha=0.9, seed=3)
    pd = api.policy_def(kind)
    kw = _kind_kwargs(kind)
    full = api.run(
        pd, trace, N, C, window=WINDOW, seed=0, horizon=T, track_opt=False,
        **kw,
    )
    # ragged chunks (prime-sized) forced through small segments: every
    # segment boundary is a carry hand-off
    chunks = (trace[i : i + 997] for i in range(0, T, 997))
    sr = run_stream(
        pd, chunks, N, C, window=WINDOW, seed=0, horizon=T,
        segment_len=2048, **kw,
    )
    assert isinstance(sr, StreamResult)
    assert sr.T == full.T and sr.n_segments > 1
    np.testing.assert_array_equal(sr.hits, full.hits)
    np.testing.assert_array_equal(sr.reward, full.reward)
    np.testing.assert_array_equal(sr.aux, full.aux)
    np.testing.assert_array_equal(sr.occupancy, full.occupancy)
    for a, b in zip(jax.tree.leaves(sr.carry), jax.tree.leaves(full.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", ("ogb", "lfu"))
def test_run_stream_from_disk_through_remap(kind):
    """The full ingestion path: sparse ids on disk -> loader chunks ->
    catalog remap -> run_stream, vs api.run over the densified trace."""
    trace = zipf(N, T, alpha=0.9, seed=5)
    sparse = trace * 1_000_003 + 17  # sparse raw ids, same structure
    with tempfile.TemporaryDirectory() as d:
        path = write_trace(os.path.join(d, "trace.csv"), sparse)
        dense = CatalogRemap().apply(sparse)
        assert dense.max() < N and len(np.unique(dense)) == len(
            np.unique(trace)
        )
        pd = api.policy_def(kind)
        kw = _kind_kwargs(kind)
        full = api.run(
            pd, dense, N, C, window=WINDOW, seed=0, horizon=T,
            track_opt=False, **kw,
        )
        cr = CatalogRemap()
        sr = run_stream(
            pd,
            cr.remap(open_trace(path, chunk_size=1013)),
            N,
            C,
            window=WINDOW,
            seed=0,
            horizon=T,
            segment_len=2048,
            **kw,
        )
        np.testing.assert_array_equal(sr.hits, full.hits)
        np.testing.assert_array_equal(sr.reward, full.reward)
        for a, b in zip(
            jax.tree.leaves(sr.carry), jax.tree.leaves(full.carry)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", ("ogb", "lru"))
def test_run_stream_over_synthesizer_chunks(kind):
    """Out-of-core synthesis == materialized synthesis, through the replay."""
    src = zipf(800, 20_000, alpha=0.9, seed=8)
    prof = fit_profile(src)
    t = 12_800
    mat = synthesize(prof, t, catalog=800, seed=4)
    pd = api.policy_def(kind)
    kw = _kind_kwargs(kind)
    full = api.run(
        pd, mat, 800, 40, window=64, seed=0, horizon=t, track_opt=False, **kw
    )
    sr = run_stream(
        pd,
        synthesize_chunks(prof, t, catalog=800, seed=4, chunk_size=3001),
        800,
        40,
        window=64,
        seed=0,
        horizon=t,
        segment_len=4096,
        **kw,
    )
    np.testing.assert_array_equal(sr.hits, full.hits)
    np.testing.assert_array_equal(sr.reward, full.reward)


def test_chunking_never_changes_the_replay():
    """Any split of the same stream gives identical results (and identical
    trailing-drop semantics)."""
    trace = zipf(N, 5000, alpha=0.8, seed=9)  # 5000 = 312*16 + 8: a tail
    pd = api.policy_def("lfu")
    results = []
    for chunk_size in (1, 97, 1024, 5000):
        chunks = (
            trace[i : i + chunk_size] for i in range(0, 5000, chunk_size)
        )
        sr = run_stream(
            pd, chunks, N, C, window=WINDOW, horizon=5000, segment_len=1024
        )
        assert sr.t_dropped == 5000 % WINDOW
        assert sr.T == 5000 - sr.t_dropped
        results.append(sr)
    for sr in results[1:]:
        np.testing.assert_array_equal(sr.hits, results[0].hits)
        np.testing.assert_array_equal(sr.reward, results[0].reward)


def test_dynamic_opt_windows():
    """dyn_opt_hits[k] is exactly the hindsight static OPT of window k,
    and the dynamic comparator dominates the static one."""
    trace = zipf(N, T, alpha=0.9, seed=11)
    pd = api.policy_def("lru")
    opt_window = 640
    sr = run_stream(
        pd, trace, N, C, window=WINDOW, horizon=T, opt_window=opt_window
    )
    assert sr.dyn_opt_window == opt_window
    assert len(sr.dyn_opt_hits) == T // opt_window
    for k in range(len(sr.dyn_opt_hits)):
        blk = trace[k * opt_window : (k + 1) * opt_window]
        assert sr.dyn_opt_hits[k] == float(best_static_hits(blk, C))
    static = float(best_static_hits(trace, C))
    assert sr.dynamic_opt_total >= static - 1e-9
    assert sr.dynamic_regret >= sr.dynamic_opt_total - float(
        sr.reward.sum()
    ) - 1e-6  # covered prefix == whole trace here
    np.testing.assert_allclose(
        sr.dyn_opt_ratio(), sr.dyn_opt_hits / opt_window
    )


def test_dynamic_opt_window_rounds_up_to_whole_windows():
    trace = zipf(N, T, alpha=0.9, seed=12)
    sr = run_stream(
        api.policy_def("fifo"), trace, N, C, window=WINDOW, horizon=T,
        opt_window=WINDOW + 1,  # not a multiple: rounds up to 2 windows
    )
    assert sr.dyn_opt_window == 2 * WINDOW


def test_stream_resume_with_carry():
    """A second run_stream resumes the first one's carry — together they
    equal one longer stream (the api.run resume contract, lifted)."""
    trace = zipf(N, T, alpha=0.9, seed=13)
    pd = api.policy_def("ftpl")
    full = run_stream(
        pd, trace, N, C, window=WINDOW, horizon=T, segment_len=1024
    )
    first = run_stream(
        pd, trace[: T // 2], N, C, window=WINDOW, horizon=T,
        segment_len=1024,
    )
    second = run_stream(
        pd, trace[T // 2 :], capacity=C, carry=first.carry, window=WINDOW,
        segment_len=1024,
    )
    np.testing.assert_array_equal(
        np.concatenate([first.hits, second.hits]), full.hits
    )
    for a, b in zip(
        jax.tree.leaves(second.carry), jax.tree.leaves(full.carry)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tracelab_public_surface():
    """The tracelab entry points re-export from the `repro` top level."""
    import repro

    assert repro.run_stream is run_stream
    assert repro.fit_profile is fit_profile
    assert repro.CatalogRemap is CatalogRemap
    assert repro.open_trace is open_trace
    assert repro.StreamResult is StreamResult


def test_stream_rejects_out_of_range_ids():
    """An id >= catalog_size would be silently clamped by the device
    gather (aliasing item N-1 into a phantom hot item) — it must raise."""
    trace = zipf(N, 2000, seed=2)
    bad = trace.copy()
    bad[777] = N + 500
    pd = api.policy_def("lru")
    with pytest.raises(ValueError, match=r"dense in \[0"):
        run_stream(pd, bad, N, C, window=WINDOW, horizon=2000)
    with pytest.raises(ValueError, match=r"dense in \[0"):
        run_stream(pd, trace - 1, N, C, window=WINDOW, horizon=2000)


def test_stream_requires_horizon_for_horizon_tuned_policies():
    """FTPL's noise scale is horizon-tuned: without an explicit horizon a
    stream would silently tune it to the first *segment* length and lose
    the bit-exact one-shot parity — so horizon is required up front."""
    trace = zipf(N, T, alpha=0.9, seed=14)
    with pytest.raises(ValueError, match="needs horizon"):
        run_stream(
            api.policy_def("ftpl"), trace, N, C, window=WINDOW,
            segment_len=2048,
        )


def test_stream_error_paths():
    trace = zipf(N, 2000, seed=1)
    pd = api.policy_def("lru")
    with pytest.raises(ValueError, match="catalog_size and capacity"):
        run_stream(pd, trace, window=WINDOW)
    with pytest.raises(ValueError, match="needs horizon"):
        run_stream(api.policy_def("ogb"), trace, N, C, window=WINDOW)
    with pytest.raises(ValueError, match="shorter than one window"):
        run_stream(pd, trace[:5], N, C, window=WINDOW, horizon=T)
    with pytest.raises(ValueError, match="opt_window needs capacity"):
        run_stream(
            pd, trace, N, carry=object(), window=WINDOW, opt_window=64
        )
    first = run_stream(pd, trace, N, C, window=WINDOW, horizon=2000)
    with pytest.raises(ValueError, match="carry's parameters"):
        run_stream(
            pd, trace, capacity=C, carry=first.carry, window=WINDOW, seed=3
        )
    # dynamic-OPT views raise cleanly when opt_window was never set
    with pytest.raises(ValueError, match="opt_window"):
        first.dynamic_regret
    with pytest.raises(ValueError, match="opt_window"):
        first.dyn_opt_ratio()
