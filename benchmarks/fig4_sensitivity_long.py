"""Paper Fig. 4 — sensitivity on LONG traces with large catalogs.

The paper's headline capability: only an O(log N) policy can even run here.
The FTPL initial noise (scaled for the long horizon) buries the counters and
drags early performance; OGB stays robust across eta."""

from __future__ import annotations


from repro.cachesim.simulator import simulate
from repro.cachesim.traces import zipf
from repro.core.ftpl import FTPL, theoretical_zeta
from repro.core.ogb import OGB, theoretical_eta
from repro.core.policies import LRU

from .common import csv_row, save_json, scale


def main() -> dict:
    N = scale(200_000, 6_800_000)
    C = N // 20
    T = scale(400_000, 35_000_000)
    trace = zipf(N, T, alpha=0.75, seed=2)

    eta0 = theoretical_eta(C, N, T)
    zeta0 = theoretical_zeta(C, N, T)
    out = {}
    for f in [0.1, 1.0, 10.0]:
        r = simulate(OGB(N, C, eta=eta0 * f), trace, window=T, record_cum=False)
        out[f"OGB_eta_x{f}"] = r.hit_ratio
        csv_row(f"fig4/OGB_eta_x{f}", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}")
    for f in [0.1, 1.0, 10.0]:
        r = simulate(FTPL(N, C, zeta=zeta0 * f), trace, window=T, record_cum=False)
        out[f"FTPL_zeta_x{f}"] = r.hit_ratio
        csv_row(f"fig4/FTPL_zeta_x{f}", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}")
    r = simulate(LRU(N, C), trace, window=T, record_cum=False)
    out["LRU"] = r.hit_ratio
    csv_row("fig4/LRU", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}")

    ogb_vals = [v for k, v in out.items() if k.startswith("OGB")]
    ftpl_vals = [v for k, v in out.items() if k.startswith("FTPL")]
    print(f"\nFig4 long-trace sensitivity (N={N} C={C} T={T}):")
    for k, v in out.items():
        print(f"  {k:>14}: hit={v:.4f}")
    spread_ogb = max(ogb_vals) - min(ogb_vals)
    spread_ftpl = max(ftpl_vals) - min(ftpl_vals)
    assert spread_ogb < spread_ftpl + 0.02
    save_json("fig4_sensitivity_long", {"N": N, "C": C, "T": T, "rows": out})
    return out


if __name__ == "__main__":
    main()
