"""Multi-tenant fleet replay at scale — one dispatch vs E sequential runs.

The ROADMAP north-star talks about heavy traffic from millions of users;
this suite measures the layer that claim stands on:
:func:`repro.cachesim.fleet.run_fleet` steps E independent per-tenant OGB
caches (heterogeneous seeds, per-tenant zipf streams) in **one** vmapped,
donated-carry compiled dispatch — >= 1000 tenants at quick scale — and is
compared against the same E replays issued as sequential ``api.run``
calls (identical executables after the first, so the gap is pure
dispatch/bookkeeping overhead).  The acceptance assert is that the fleet
dispatch wins on aggregate us/request.

Also measured: the fixed-memory ``run_fleet_stream`` leg over
stats-matched ``tracelab.tenant_streams`` (asserted bit-exact against the
in-memory fleet), and the two-level ``edge_fleet_cdn`` scenario (E edge
LRUs in front of one shared no-regret origin) with mean / p5 / p95 tenant
hit ratios.

Writes ``benchmarks/results/fleet_scale.json`` and the tracked top-level
``BENCH_fleet.json`` (same pattern as ``BENCH_stream.json``).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax

from repro.cachesim.api import policy_def, run
from repro.cachesim.fleet import (
    run_fleet,
    run_fleet_stream,
    run_edge_fleet_scenario,
)
from repro.cachesim.tracelab import fit_profile, tenant_streams
from repro.cachesim.traces import make_trace

from .common import SCALE, check_finite, csv_row, save_json

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fleet.json",
)

#: per-scale (E, N, C, T_per_tenant, window); quick meets the >=1000-tenant
#: single-dispatch acceptance bar on one CPU
CONFIGS = {
    "mini": (128, 256, 16, 512, 128),
    "quick": (1024, 1024, 64, 1024, 256),
    "full": (4096, 4096, 256, 4096, 512),
}

#: sequential tenants actually timed (the per-call overhead is uniform, so
#: a sample extrapolates; running all 4096 full-scale singles is pointless)
MAX_SEQUENTIAL = 256


def main() -> dict:
    scale_name = SCALE if SCALE in CONFIGS else "quick"
    n_tenants, n, c, t, w = CONFIGS[scale_name]
    pd = policy_def("ogb")

    traces = np.stack(
        [
            make_trace("zipf", n, t, seed=e, alpha=0.9)
            for e in range(n_tenants)
        ]
    )

    out = {
        "scale": scale_name,
        "E": n_tenants,
        "N": n,
        "C": c,
        "T_per_tenant": t,
        "window": w,
        "backend": jax.default_backend(),
        "rows": [],
    }

    # ---- fleet: one compiled dispatch over every tenant ------------------
    # warmup run charges compile time, then the timed run measures dispatch
    run_fleet(pd, traces, n, c, window=w, track_opt=False, keep_carry=False)
    fleet = run_fleet(
        pd, traces, n, c, window=w, track_opt=False, keep_carry=False
    )
    assert fleet.n_tenants == n_tenants
    csv_row(
        f"fleet/one-dispatch/E={n_tenants}",
        fleet.us_per_request,
        f"agg_hit={fleet.hit_ratio:.4f} "
        f"req/s={fleet.requests_per_second:,.0f}",
    )

    # ---- sequential baseline: E independent api.run calls ----------------
    # one warmup call compiles the single-tenant executable; the timed loop
    # then pays only per-call dispatch — the fairest possible baseline
    run(pd, traces[0], n, c, window=w, seed=0, track_opt=False,
        keep_carry=False)
    n_seq = min(n_tenants, MAX_SEQUENTIAL)
    seq_wall = 0.0
    t0 = time.perf_counter()
    for e in range(n_seq):
        res = run(
            pd, traces[e], n, c, window=w, seed=e, track_opt=False,
            keep_carry=False,
        )
        seq_wall += res.wall_seconds
    seq_loop = time.perf_counter() - t0
    seq_us = 1e6 * seq_wall / (n_seq * t)
    csv_row(
        f"fleet/sequential/E={n_seq}",
        seq_us,
        f"loop_wall={seq_loop:.2f}s (sample of {n_seq}/{n_tenants})",
    )

    out["rows"].append(
        {
            "leg": "one_dispatch",
            "E": n_tenants,
            "us_per_request": fleet.us_per_request,
            "requests_per_second": fleet.requests_per_second,
            "hit_ratio": fleet.hit_ratio,
        }
    )
    out["rows"].append(
        {
            "leg": "sequential",
            "E": n_seq,
            "us_per_request": seq_us,
            "loop_wall_seconds": seq_loop,
        }
    )
    speedup = seq_us / fleet.us_per_request
    out["fleet_speedup_vs_sequential"] = speedup
    print(
        f"fleet: {n_tenants} tenants in one dispatch at "
        f"{fleet.us_per_request:.3f} us/req vs sequential "
        f"{seq_us:.3f} us/req -> {speedup:.1f}x"
    )
    assert fleet.us_per_request < seq_us, (
        f"one-dispatch fleet ({fleet.us_per_request:.3f} us/req) must beat "
        f"{n_seq} sequential api.run calls ({seq_us:.3f} us/req)"
    )

    # ---- streamed fleet over stats-matched tenant streams ----------------
    e_s = min(n_tenants, 128)
    t_s = 4 * w
    profile = fit_profile(traces[0])
    stream = run_fleet_stream(
        pd,
        tenant_streams(profile, e_s, t_s, catalog=n, base_seed=3),
        n,
        c,
        window=w,
        horizons=t_s,
        segment_len=2 * w,
        keep_carry=False,
    )
    # the stream must replay exactly what the in-memory fleet replays
    mem_traces = np.stack(
        [
            np.concatenate(
                list(tenant_streams(profile, e_s, t_s, catalog=n,
                                    base_seed=3)[e])
            )
            for e in range(e_s)
        ]
    )
    mem = run_fleet(
        pd, mem_traces, n, c, window=w, horizons=t_s, track_opt=False,
        keep_carry=False,
    )
    assert np.array_equal(stream.hits, mem.hits), (
        "run_fleet_stream diverged from in-memory run_fleet"
    )
    csv_row(
        f"fleet/stream/E={e_s}",
        stream.us_per_request,
        f"req/s={stream.requests_per_second:,.0f} "
        f"segments={stream.n_segments} prefetch={stream.prefetch}",
    )
    out["rows"].append(
        {
            "leg": "stream",
            "E": e_s,
            "T_per_tenant": stream.T,
            "us_per_request": stream.us_per_request,
            "requests_per_second": stream.requests_per_second,
            "segments": stream.n_segments,
            "prefetch": stream.prefetch,
        }
    )

    # ---- two-level edge -> origin scenario -------------------------------
    ef_scale = "mini" if scale_name == "mini" else "quick"
    ef = run_edge_fleet_scenario("edge_fleet_cdn", ef_scale)
    csv_row(
        f"fleet/edge_fleet/E={ef.edges.n_tenants}",
        ef.edges.us_per_request,
        f"e2e_hit={ef.end_to_end_hit_ratio:.4f}",
    )
    out["edge_fleet"] = {
        "scale": ef_scale,
        "E": ef.edges.n_tenants,
        "edge_hit_mean": ef.edges.hit_ratio_mean,
        "edge_hit_p5": ef.edges.hit_ratio_p5,
        "edge_hit_p95": ef.edges.hit_ratio_p95,
        "origin_hit_ratio": ef.origin_hit_ratio,
        "origin_requests": ef.origin_requests,
        "end_to_end_hit_ratio": ef.end_to_end_hit_ratio,
        "edge_regret_mean": float(ef.edges.regrets.mean()),
    }
    print(
        f"edge_fleet: {ef.edges.n_tenants} edges "
        f"(hit mean={ef.edges.hit_ratio_mean:.4f} "
        f"p5={ef.edges.hit_ratio_p5:.4f} p95={ef.edges.hit_ratio_p95:.4f}) "
        f"-> origin hit={ef.origin_hit_ratio:.4f}; "
        f"end-to-end {ef.end_to_end_hit_ratio:.4f}"
    )
    # the shared origin must recover a real fraction of the edge misses —
    # the whole point of the two-level topology
    assert ef.end_to_end_hit_ratio > ef.edges.hit_ratio, (
        ef.end_to_end_hit_ratio,
        ef.edges.hit_ratio,
    )

    check_finite(out)
    save_json("fleet_scale", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
