"""Paper Fig. 9 — cache occupancy variability (left) and removed items per
request (right).

Claims: occupancy stays within ~0.5% of C; Algorithm 2's zero-pop loop
removes < 0.5 items per request on average."""

from __future__ import annotations

import numpy as np

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import shifting_zipf, zipf
from repro.core.ogb import OGB

from .common import csv_row, save_json, scale


def main() -> dict:
    N = scale(40_000, 1_000_000)
    C = N // 10
    T = scale(150_000, 5_000_000)
    out = {}
    for tname, trace in {
        "cdn_like": zipf(N, T, alpha=0.9, seed=7),
        "ms_ex_like": shifting_zipf(N, T, alpha=0.9, phase=T // 6, seed=8),
    }.items():
        ogb = OGB(N, C, horizon=T, batch_size=1, lazy_init=False, seed=0)
        res = simulate(ogb, trace, window=T, occupancy_every=max(T // 50, 1),
                       record_cum=False)
        occ = np.asarray(res.occupancy, dtype=float)
        dev = np.abs(occ - C) / C
        removals_per_req = ogb.stats.zero_pops / max(ogb.stats.requests, 1)
        out[tname] = {
            "occ_mean": float(occ.mean()),
            "occ_max_dev_pct": float(100 * dev.max()),
            "removals_per_request": float(removals_per_req),
            "hit_ratio": res.hit_ratio,
        }
        csv_row(
            f"fig9/{tname}",
            res.us_per_request,
            f"max_dev_pct={100 * dev.max():.3f};removals={removals_per_req:.3f}",
        )
        print(
            f"{tname}: occupancy mean={occ.mean():.1f} (C={C}), "
            f"max dev={100 * dev.max():.2f}%, removals/req={removals_per_req:.3f}"
        )
        # paper: variability limited (CV <= 1/sqrt(C)); removals < 0.5/request
        assert dev.max() < max(5 / np.sqrt(C), 0.02), dev.max()
        assert removals_per_req < 1.5
    save_json("fig9_occupancy", out)
    return out


if __name__ == "__main__":
    main()
