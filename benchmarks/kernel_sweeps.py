"""Pallas kernel analysis: HBM-sweep counts + correctness-at-scale.

On this CPU host wall-clock of interpret-mode kernels is meaningless, so the
metric is the *structural* one that determines TPU time for these memory-
bound ops: catalog sweeps over HBM per projection.

  naive bisection:   K sweeps (K ~= 50 for fp32-accurate tau)
  fused K-candidate: passes + 1 sweeps (default 3 + 1 apply)

The benchmark validates the fused kernel's tau against the float64 oracle
across catalog sizes (the accuracy that justifies the sweep reduction) and
reports the sweep ratio; jnp reference wall-clock is included as a sanity
signal only.
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core.projection import project_capped_simplex
from repro.kernels.capped_simplex.ops import fused_ogb_update

from .common import csv_row, save_json, scale


def main() -> dict:
    out = {}
    passes, K = 3, 64
    bisect_iters = 50
    sweep_ratio = bisect_iters / (passes + 1)
    for n in scale([65_536, 1_048_576], [1_048_576, 16_777_216, 134_217_728]):
        rng = np.random.default_rng(0)
        C = n // 64
        f = np.full(n, C / n, np.float32)
        ids = rng.integers(0, n, size=4096)
        counts = np.bincount(ids, minlength=n).astype(np.float32)
        eta = 0.01

        t0 = time.perf_counter()
        got = np.asarray(
            fused_ogb_update(jnp.asarray(f), jnp.asarray(counts), eta, float(C),
                             passes=passes, k=K)
        )
        t_fused = time.perf_counter() - t0
        expect = project_capped_simplex(f.astype(np.float64) + eta * counts, C)
        err = float(np.abs(got - expect).max())
        out[n] = {
            "max_err": err,
            "hbm_sweeps_fused": passes + 1,
            "hbm_sweeps_bisect": bisect_iters,
            "sweep_ratio": sweep_ratio,
            "interpret_wall_s": t_fused,
        }
        csv_row(f"kernel/capped_simplex/N={n}", 1e6 * t_fused,
                f"max_err={err:.2e};sweep_ratio={sweep_ratio:.1f}x")
        print(f"N={n:>11,}: fused max_err={err:.2e}  "
              f"sweeps {passes + 1} vs {bisect_iters} (ratio {sweep_ratio:.1f}x)")
        assert err < 5e-4
    save_json("kernel_sweeps", out)
    return out


if __name__ == "__main__":
    main()
