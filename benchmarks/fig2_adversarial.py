"""Paper Fig. 2 — adversarial round-robin trace.

Claim reproduced: recency/frequency policies collapse (linear regret) while
gradient policies track OPT = C/N; OGB == OGB_cl for B=1 (footnote 3)."""

from __future__ import annotations

import numpy as np

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import adversarial
from repro.core.ogb import OGB
from repro.core.ogb_classic import OGBClassic
from repro.core.regret import best_static_hits

from .common import csv_row, make_policies, save_json, scale


def main() -> dict:
    N = scale(1000, 1000)
    C = N // 4
    T = scale(60_000, 1_000_000)
    trace = adversarial(N, T, seed=0)
    opt_ratio = C / N

    policies = make_policies(N, C, T)
    policies["OGB_cl(B=1)"] = OGBClassic(N, C, horizon=T, batch_size=1)
    rows = {}
    for name, p in policies.items():
        res = simulate(p, trace, window=max(T // 20, 1), record_cum=False)
        rows[name] = {
            "hit_ratio": res.hit_ratio,
            "us_per_request": res.us_per_request,
        }
        csv_row(f"fig2/{name}", res.us_per_request, f"hit_ratio={res.hit_ratio:.4f}")
    rows["OPT"] = {"hit_ratio": opt_ratio}
    csv_row("fig2/OPT", 0.0, f"hit_ratio={opt_ratio:.4f}")

    print(f"\nFig2 adversarial N={N} C={C} T={T} (OPT={opt_ratio:.3f}):")
    for k, v in rows.items():
        print(f"  {k:>12}: hit={v['hit_ratio']:.4f}")
    # assertions mirroring the figure
    assert rows["OGB"]["hit_ratio"] > 0.7 * opt_ratio
    assert rows["LRU"]["hit_ratio"] < 0.2 * opt_ratio
    save_json("fig2_adversarial", {"N": N, "C": C, "T": T, "rows": rows})
    return rows


if __name__ == "__main__":
    main()
