"""Paper Fig. 2 — adversarial round-robin trace, via the scan engines.

Claim reproduced: recency/frequency policies collapse (linear regret) while
gradient policies track OPT = C/N.  Every policy now runs device-resident
(:mod:`repro.cachesim.engines` / :mod:`repro.cachesim.replay`) through the
``fig2_adversarial`` scenario; the host-side OGB_cl(B=1) footnote-3 check
stays on the slow oracle path at quick scale only."""

from __future__ import annotations

from repro.cachesim.scenarios import get_scenario, run_scenario
from repro.cachesim.simulator import simulate
from repro.core.ogb_classic import OGBClassic

from .common import SCALE, check_finite, csv_row, save_json


def main() -> dict:
    scale = "full" if SCALE == "full" else "quick"
    sc = get_scenario("fig2_adversarial")
    N, T, C = sc.dims(scale)
    trace = sc.make_trace(scale)  # generated once, shared by every driver
    res = run_scenario("fig2_adversarial", scale=scale, trace=trace)
    opt_ratio = C / N

    rows = {
        name: dict(row) for name, row in res.rows.items()
    }
    if scale == "quick":
        # footnote 3: OGB == OGB_cl for B=1 — host oracle, toy scale only
        r = simulate(
            OGBClassic(N, C, horizon=T, batch_size=1),
            trace,
            window=max(T // 20, 1),
            record_cum=False,
        )
        rows["OGB_cl(B=1)"] = {
            "hit_ratio": r.hit_ratio,
            "us_per_request": r.us_per_request,
        }
    for name, row in rows.items():
        csv_row(
            f"fig2/{name}",
            row.get("us_per_request", 0.0),
            f"hit_ratio={row['hit_ratio']:.4f}",
        )

    print(f"\nFig2 adversarial N={N} C={C} T={T} (OPT={opt_ratio:.3f}):")
    for k, v in rows.items():
        print(f"  {k:>12}: hit={v['hit_ratio']:.4f}")
    # assertions mirroring the figure
    assert rows["OGB"]["hit_ratio"] > 0.7 * opt_ratio
    assert rows["OMD"]["hit_ratio"] > 0.7 * opt_ratio
    assert rows["LRU"]["hit_ratio"] < 0.2 * opt_ratio
    assert rows["LFU"]["hit_ratio"] < 0.2 * opt_ratio
    payload = {"N": N, "C": C, "T": T, "rows": rows}
    check_finite(payload)
    save_json("fig2_adversarial", payload)
    return rows


if __name__ == "__main__":
    main()
