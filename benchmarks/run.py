"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus readable summaries) and
writes JSON to benchmarks/results/.  REPRO_BENCH_SCALE=full for paper-scale
runs; default sizes finish in minutes on one CPU core.

  python -m benchmarks.run            # all figures
  python -m benchmarks.run fig2 fig9  # a subset
  python -m benchmarks.run --list     # print registered suite names
"""

from __future__ import annotations

import sys
import traceback

from . import (
    complexity_scaling,
    engines_throughput,
    kernel_sweeps,
    fig2_adversarial,
    fig3_sensitivity_short,
    fig4_sensitivity_long,
    fig7_8_traces,
    fig9_occupancy,
    fig10_batched,
    fig11_locality,
    fleet_scale,
    serving_slo,
    sized_cdn,
    stream_scale,
    throughput,
)

SUITES = {
    "fig2": fig2_adversarial.main,
    "fig3": fig3_sensitivity_short.main,
    "fig4": fig4_sensitivity_long.main,
    "fig7_8": fig7_8_traces.main,
    "fig9": fig9_occupancy.main,
    "fig10": fig10_batched.main,
    "fig11": fig11_locality.main,
    "complexity": complexity_scaling.main,
    "kernels": kernel_sweeps.main,
    "throughput": throughput.main,
    "engines": engines_throughput.main,
    "serving": serving_slo.main,
    "sized": sized_cdn.main,
    "stream": stream_scale.main,
    "fleet": fleet_scale.main,
}


def _roofline():
    # imported lazily: needs dry-run artifacts to exist
    from . import roofline

    return roofline.main()


SUITES["roofline"] = _roofline


def main() -> None:
    if any(a in ("--list", "-l") for a in sys.argv[1:]):
        for name in sorted(SUITES):
            print(name)
        return
    wanted = sys.argv[1:] or list(SUITES)
    # a typo'd suite name must fail the run, not silently skip the suite
    unknown = [n for n in wanted if n not in SUITES]
    if unknown:
        print(f"unknown suites {unknown}; available: {sorted(SUITES)}")
        raise SystemExit(2)
    failures = []
    for name in wanted:
        print(f"\n=== {name} " + "=" * (70 - len(name)))
        try:
            SUITES[name]()
        except Exception as e:  # reprolint: allow(broad-except) recorded; exits 1 below
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        print("\nFAILED suites:", [n for n, _ in failures])
        raise SystemExit(1)
    print("\nall benchmark suites passed")


if __name__ == "__main__":
    main()
