"""Paper Fig. 10 — batched operation (B sweep), fractional setting.

cdn-like traffic is insensitive to B (items re-requested throughout);
twitter-like traffic loses hits once B exceeds the burst lifetime.
Fractional rewards computed with the unified scan engine
(``api.run(policy_def("ogb", sample="none"), ...)``) — the whole B-sweep
runs on device."""

from __future__ import annotations

import numpy as np

from repro.cachesim.api import policy_def, run
from repro.cachesim.traces import bursty, zipf
from repro.core.ogb import theoretical_eta

from .common import csv_row, save_json, scale, timed


def run_fractional(trace: np.ndarray, N: int, C: int, B: int) -> float:
    T = len(trace)
    eta = theoretical_eta(C, N, T, B)
    m = run(
        policy_def("ogb", sample="none"), trace, N, C,
        window=B, eta=eta, track_opt=False,
    )
    return m.frac_hit_ratio


def main() -> dict:
    # quick scale keeps T/B >= ~300 policy updates at the largest B so the
    # gradient policy actually converges (the paper's cdn run has 3.5e4
    # updates at B=1000); full scale matches the paper's trace sizes.
    T = scale(300_000, 4_000_000)
    Bs = scale([1, 100, 1000], [1, 100, 1000, 10_000])
    configs = {
        # cdn-like: heavy-skew stationary catalog, every item long-lived
        "cdn_like": (scale(500, 1_000_000), lambda N: zipf(N, T, alpha=1.0, seed=9)),
        # twitter-like: bursty short-lived items carry real hit mass
        "twitter_like": (scale(2_000, 1_000_000), lambda N: bursty(N, T, seed=10)),
    }
    out = {}
    for tname, (N, gen) in configs.items():
        C = N // 20
        trace = gen(N)
        rows = {}
        for B in Bs:
            if B > T // 100:
                continue
            (ratio), dt = timed(run_fractional, trace, N, C, B)
            rows[B] = ratio
            csv_row(f"fig10/{tname}/B={B}", 1e6 * dt / T, f"frac_hit={ratio:.4f}")
        out[tname] = rows
        print(f"{tname}: " + "  ".join(f"B={b}:{v:.4f}" for b, v in rows.items()))
    # claims: cdn nearly flat in B; twitter degrades markedly (bursts die)
    cdn = out["cdn_like"]
    tw = out["twitter_like"]
    rel_cdn = (cdn[1] - cdn[1000]) / max(cdn[1], 1e-9)
    rel_tw = (tw[1] - tw[1000]) / max(tw[1], 1e-9)
    assert rel_cdn < 0.2, rel_cdn
    assert rel_tw > rel_cdn + 0.1, (rel_tw, rel_cdn)
    save_json("fig10_batched", out)
    return out


if __name__ == "__main__":
    main()
