"""Out-of-core streaming replay at scale — the fixed-memory ledger.

The paper's headline regime is millions of requests over a large catalog;
this suite proves the tracelab path holds it in **fixed memory**: a
stats-matched synthesized workload (twitter-shaped: zipf base + one-shot
/ burst overlay) is streamed through :func:`repro.cachesim.tracelab.run_stream`
for OGB (fractional gradient) and LFU (discrete automaton) at increasing
T — up to 1e7 requests at full scale — **without ever materializing the
trace**.  After each run the process high-water RSS is recorded; the
acceptance assert is that peak RSS is independent of T (the growth from
the smallest to the largest T stays far below what materializing the
largest trace would cost).  A us/request budget guards against gross
throughput regressions on the streaming path.

Writes ``benchmarks/results/stream_scale.json`` and the tracked top-level
``BENCH_stream.json`` (same pattern as ``BENCH_engines.json``).

Scales (``REPRO_BENCH_SCALE``): ``mini`` (CI smoke, seconds), ``quick``
(default, ~1 min), ``full`` (T=1e7, a few minutes on one CPU core).
"""

from __future__ import annotations

import json
import os
import resource


import jax

from repro.cachesim.api import policy_def
from repro.cachesim.tracelab import fit_profile, run_stream, synthesize_chunks
from repro.cachesim.traces import make_trace

from .common import SCALE, check_finite, csv_row, save_json

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_stream.json",
)

US_PER_REQUEST_BUDGET = {"ogb": 15.0, "lfu": 50.0}

#: one segment shape shared by every (kind, T) run: all CONFIG Ts are
#: multiples, so each kind compiles exactly one executable during warmup
#: and the RSS deltas across T measure streaming memory, not compile pools
SEGMENT_LEN = 50_000

#: per-scale (N, C, [T ascending]) — LFU is O(C) per request, so C sets its
#: wall clock; the acceptance criterion is defined at full scale (T=1e7)
CONFIGS = {
    "mini": (20_000, 1_000, [50_000, 200_000]),
    "quick": (100_000, 2_000, [200_000, 2_000_000]),
    "full": (100_000, 2_000, [1_000_000, 10_000_000]),
}


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> dict:
    scale_name = SCALE if SCALE in CONFIGS else "quick"
    n, c, t_list = CONFIGS[scale_name]

    # twitter-shaped profile fitted on a sampled source (the real_like flow)
    source = make_trace(
        "bursty", min(n, 20_000), 200_000, seed=17,
        burst_fraction=0.5, burst_len_mean=8.0, burst_span=60,
    )
    profile = fit_profile(source)

    out = {
        "scale": scale_name,
        "N": n,
        "C": c,
        "backend": jax.default_backend(),
        "window": {"ogb": 1_000, "lfu": 10_000},
        "profile": {
            "oneshot_frac": profile.oneshot_frac,
            "burst_frac": profile.burst_frac,
            "drift_phase": profile.drift_phase,
        },
        "rows": [],
    }

    # warmup at the smallest T so compile-time allocations and the device
    # pool are charged to the baseline, not to the T-scaling deltas
    for kind in ("ogb", "lfu"):
        run_stream(
            policy_def(kind),
            synthesize_chunks(profile, t_list[0], catalog=n, seed=5),
            n, c, window=out["window"][kind], horizon=t_list[0],
            segment_len=SEGMENT_LEN, keep_carry=False,
        )

    rss_after = {}
    for t in t_list:  # ascending: ru_maxrss is a monotone high-water mark
        for kind in ("ogb", "lfu"):
            chunks = synthesize_chunks(
                profile, t, catalog=n, seed=5, chunk_size=65_536
            )
            res = run_stream(
                policy_def(kind),
                chunks,
                n,
                c,
                window=out["window"][kind],
                horizon=t,
                segment_len=SEGMENT_LEN,
                opt_window=max(t // 50, out["window"][kind]),
                keep_carry=False,
            )
            rss_after[(kind, t)] = _rss_mb()
            row = {
                "kind": kind,
                "T": t,
                "us_per_request": res.us_per_request,
                "hit_ratio": res.hit_ratio,
                "dynamic_opt_ratio": res.dynamic_opt_total / res.T,
                "dynamic_regret": res.dynamic_regret,
                "segments": res.n_segments,
                "rss_mb": rss_after[(kind, t)],
            }
            out["rows"].append(row)
            csv_row(
                f"stream/{kind}/T={t}",
                res.us_per_request,
                f"hit={res.hit_ratio:.4f} rss={row['rss_mb']:.0f}MB",
            )

    # --- fixed-memory acceptance: peak RSS must not scale with T.  The
    # growth across a >=10x T increase stays far below the cost of
    # materializing the largest trace (which is what this path replaces).
    trace_mb = t_list[-1] * 8 / 1e6
    threshold_mb = max(24.0, 0.5 * trace_mb)
    deltas = {}
    for kind in ("ogb", "lfu"):
        delta = rss_after[(kind, t_list[-1])] - rss_after[(kind, t_list[0])]
        deltas[kind] = delta
        print(
            f"stream/{kind}: peak-RSS delta {delta:.1f}MB over a "
            f"{t_list[-1] // t_list[0]}x T increase "
            f"(materialized trace would be {trace_mb:.0f}MB; "
            f"budget {threshold_mb:.0f}MB)"
        )
        assert delta < threshold_mb, (
            f"{kind}: peak RSS grew {delta:.1f}MB from T={t_list[0]} to "
            f"T={t_list[-1]} (>{threshold_mb:.0f}MB): the stream is no "
            "longer fixed-memory"
        )
    out["rss_delta_mb"] = deltas
    out["rss_threshold_mb"] = threshold_mb

    for row in out["rows"]:
        budget = US_PER_REQUEST_BUDGET[row["kind"]]
        assert row["us_per_request"] < budget, (
            row["kind"], row["T"], row["us_per_request"], budget,
        )

    check_finite(out)
    save_json("stream_scale", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
