"""End-to-end replay throughput: one compiled scan vs per-batch dispatch.

The per-batch driver dispatches one ``ogb_batch_update`` per request chunk and
syncs the reward scalar back to the host every step — the harness overhead the
paper's complexity argument says must not exist.  The scan engine
(:mod:`repro.cachesim.replay`) compiles the whole replay into one
``lax.scan`` with a donated carry and a warm-started projection (single-digit
catalog sweeps instead of ~50 cold bisection sweeps), so the only host
round-trip is the final metrics fetch.

Writes ``benchmarks/results/throughput.json`` and the tracked top-level
``BENCH_throughput.json`` so the perf trajectory is visible PR over PR.
Compile time is excluded on both sides (AOT-compiled scan; warmed jit cache
for the per-batch path) — we are measuring steady-state replay throughput.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.cachesim.replay import ReplayCarry, make_replay_fn
from repro.cachesim.traces import zipf
from repro.core.ogb import theoretical_eta
from repro.jaxcache.fractional import (
    DEFAULT_WARM_SWEEPS,
    FractionalState,
    ogb_batch_update,
    permanent_random_numbers,
    poisson_sample,
)

from .common import csv_row, save_json, scale

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_throughput.json",
)


def run_per_batch(
    trace: np.ndarray, N: int, C: int, B: int, eta: float, repeats: int = 2
):
    """The old harness: one dispatch + one host sync per chunk."""
    n_batches = len(trace) // B
    warm = ogb_batch_update(
        FractionalState.create(N, C), jnp.zeros(B, jnp.int32), jnp.float32(eta), C
    )
    jax.block_until_ready(warm[0].f)

    p = permanent_random_numbers(jax.random.key(0), N)
    best = float("inf")
    for _ in range(repeats):
        state = FractionalState.create(N, C)
        reward = 0.0
        hits = 0
        t0 = time.perf_counter()
        for i in range(n_batches):
            ids = jnp.asarray(trace[i * B : (i + 1) * B], jnp.int32)
            cached = poisson_sample(state.f, p, C)
            hits += int(jnp.sum(cached[ids]))
            state, r = ogb_batch_update(state, ids, jnp.float32(eta), C)
            reward += float(r)  # the per-batch host sync the scan removes
        jax.block_until_ready(state.f)
        best = min(best, time.perf_counter() - t0)
    return {"frac_reward": reward, "hits": hits, "wall_s": best}


def run_scan(
    trace: np.ndarray,
    N: int,
    C: int,
    B: int,
    eta: float,
    projection: str = "warm",
):
    """The new engine, AOT-compiled so compile time is not billed."""
    m = len(trace) // B
    chunks = jnp.asarray(
        np.asarray(trace[: m * B]).reshape(m, B), jnp.int32
    )
    p = permanent_random_numbers(jax.random.key(0), N)
    us = jnp.zeros((0,), jnp.float32)
    fn = make_replay_fn(N, C, B, sample="poisson", projection=projection)
    compiled = fn.lower(
        ReplayCarry.create(N, C), chunks, jnp.float32(eta), p, us
    ).compile()
    best = float("inf")
    for _ in range(2):
        carry = ReplayCarry.create(N, C)
        t0 = time.perf_counter()
        carry, opt, (reward, hits, taus, occ) = compiled(
            carry, chunks, jnp.float32(eta), p, us
        )
        jax.block_until_ready((carry.f, opt, reward, hits, taus, occ))
        best = min(best, time.perf_counter() - t0)
    wall = best
    return {
        "frac_reward": float(jnp.sum(reward)),
        "hits": int(jnp.sum(hits)),
        "opt_hits": float(opt),
        "taus": np.asarray(taus, np.float64),
        "wall_s": wall,
    }


def main() -> dict:
    T = scale(200_000, 4_000_000)
    B = 1000
    sizes = scale([10_000, 100_000, 1_000_000], [10_000, 100_000, 1_000_000, 10_000_000])
    out = {"T": T, "B": B, "backend": jax.default_backend(), "sizes": {}}
    for N in sizes:
        C = N // 20
        eta = theoretical_eta(C, N, T, B)
        trace = zipf(N, T, alpha=0.8, seed=21)
        scan = run_scan(trace, N, C, B, eta)
        batch = run_per_batch(trace, N, C, B, eta)
        speedup = batch["wall_s"] / scan["wall_s"]
        # the two drivers must agree on the replay itself
        rel = abs(scan["frac_reward"] - batch["frac_reward"]) / max(
            batch["frac_reward"], 1e-9
        )
        assert rel < 1e-3, (scan["frac_reward"], batch["frac_reward"])
        # warm-Newton and cold-bisection f trajectories differ at ~1e-6, so a
        # Poisson comparison with |f_i - p_i| below that can flip either way —
        # allow a handful of per-request disagreements, not bit equality
        assert abs(scan["hits"] - batch["hits"]) <= max(5, int(1e-5 * T)), (
            scan["hits"],
            batch["hits"],
        )
        row = {
            "scan_us_per_req": 1e6 * scan["wall_s"] / T,
            "batch_us_per_req": 1e6 * batch["wall_s"] / T,
            "speedup": speedup,
            "frac_hit_ratio": scan["frac_reward"] / T,
            "hit_ratio": scan["hits"] / T,
        }
        out["sizes"][N] = row
        csv_row(
            f"throughput/N={N}/scan", row["scan_us_per_req"], f"speedup={speedup:.2f}x"
        )
        csv_row(f"throughput/N={N}/per_batch", row["batch_us_per_req"], "")
        print(
            f"N={N:>10,}: scan {row['scan_us_per_req']:8.3f} us/req   "
            f"per-batch {row['batch_us_per_req']:8.3f} us/req   "
            f"speedup {speedup:5.2f}x"
        )

    # warm-started projection == cold bisection, at single-digit sweeps
    N_eq = sizes[min(1, len(sizes) - 1)]
    C_eq = N_eq // 20
    eta_eq = theoretical_eta(C_eq, N_eq, T, B)
    tr_eq = zipf(N_eq, T, alpha=0.8, seed=22)[: 50 * B]
    warm = run_scan(tr_eq, N_eq, C_eq, B, eta_eq, projection="warm")
    cold = run_scan(tr_eq, N_eq, C_eq, B, eta_eq, projection="bisect")
    tau_diff = float(np.max(np.abs(warm["taus"] - cold["taus"])))
    out["warm_vs_cold_tau_max_diff"] = tau_diff
    out["warm_sweeps"] = DEFAULT_WARM_SWEEPS
    print(
        f"warm({DEFAULT_WARM_SWEEPS} sweeps) vs cold(50 sweeps) "
        f"tau max diff: {tau_diff:.2e}"
    )
    assert tau_diff < 1e-6, tau_diff

    largest = max(out["sizes"])
    # shared-CPU boxes time the two drivers with ~2x run-to-run variance in
    # opposite directions (best-of-2 narrows but does not close it): 3x is
    # the level that separates signal from that noise while still proving
    # the dispatch/projection overhead claim
    assert out["sizes"][largest]["speedup"] >= 3.0, out["sizes"][largest]
    save_json("throughput", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
