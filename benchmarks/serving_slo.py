"""Continuous-serving latency SLO — the "heavy traffic" artifact.

Every other suite replays a dead trace and reports amortized us/request;
this one measures what serving actually pays: **per-decision latency
under sustained open-loop arrivals**.  Two decision paths are driven
through :class:`repro.serve.engine.ContinuousServingLoop`:

* ``expert_cache`` — one :class:`~repro.serve.expert_cache.OGBExpertCache`
  decision per arriving routed-count vector (the MoE serving hot path);
* ``stream_window`` — one resumable ``api.run(carry=...)`` window per
  arriving id batch (the paper's B-batched online decision, as a serving
  step instead of a replay chunk).

Arrivals are open-loop at ~70% of the measured offline capacity, so the
p99 includes real queueing delay without saturating; each track reports
p50/p99/mean decision latency and sustained requests/sec.

The second half pins the async streaming pipeline's win: the
``stream_scale`` quick shape replayed through ``run_stream`` with
``prefetch=0`` (synchronous) vs ``prefetch=2`` (double-buffered), with
the :class:`~repro.cachesim.results.StreamResult` timing split showing
the ingest/device overlap and a bit-exactness check on the hits.  The
acceptance assert is **async throughput >= synchronous** (the device no
longer waits for host ingest) — on multi-core hosts; a single-CPU host
has no second core to overlap into, so there the floor degrades to a
bounded-overhead check (``SINGLE_CORE_FLOOR``) and the recorded
``cpu_count`` says why.

Writes ``benchmarks/results/serving_slo.json`` and the tracked top-level
``BENCH_serving.json``.

Scales (``REPRO_BENCH_SCALE``): ``mini`` (CI smoke, seconds), ``quick``
(default, ~1 min), ``full`` (a few minutes).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax

from repro.cachesim import api
from repro.cachesim.tracelab import fit_profile, run_stream, synthesize_chunks
from repro.cachesim.traces import make_trace
from repro.serve.engine import ContinuousServingLoop
from repro.serve.expert_cache import ExpertCacheConfig, OGBExpertCache

from .common import SCALE, check_finite, csv_row, save_json

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json",
)

#: fraction of measured offline capacity offered as the open-loop rate —
#: high enough that queueing is real, low enough that p99 is an SLO and
#: not a saturation artifact
LOAD_FACTOR = 0.7

#: per-scale knobs: serving decisions per track, expert-cache geometry,
#: and the streaming shape.  The quick stream shape matches
#: ``stream_scale`` (N=100k, C=2k) — the acceptance criterion is defined
#: there; the stream window is 250 so the device scan is a real fraction
#: of the pipeline (at window=1000 ingest is ~95% of the wall and there
#: is nothing left to overlap); mini tolerates CI-runner noise.
CONFIGS = {
    "mini": {
        "serve_steps": 200,
        "layers": 2,
        "experts": 32,
        "window": 500,
        "stream": dict(
            n=20_000, c=1_000, t=100_000, window=250, repeats=2,
            min_speedup=0.85,
        ),
    },
    "quick": {
        "serve_steps": 1_000,
        "layers": 4,
        "experts": 64,
        "window": 1_000,
        "stream": dict(
            n=100_000, c=2_000, t=1_000_000, window=250, repeats=3,
            min_speedup=1.0,
        ),
    },
    "full": {
        "serve_steps": 5_000,
        "layers": 8,
        "experts": 64,
        "window": 1_000,
        "stream": dict(
            n=100_000, c=2_000, t=2_000_000, window=250, repeats=3,
            min_speedup=1.0,
        ),
    },
}

SEGMENT_LEN = 50_000

#: overlap needs a second core: on a single-CPU host the ingest thread,
#: the XLA compute pool, and the main loop time-slice one core, so total
#: work is conserved and the pipeline can only break even.  There the
#: assert degrades to "the pipeline overhead stays bounded".
SINGLE_CORE_FLOOR = 0.85


def _slo_row(name: str, slo, rate: float, extra=None) -> dict:
    row = {
        "track": name,
        "offered_rate": rate,
        "requests": slo.requests,
        "req_per_sec": slo.req_per_sec,
        "p50_ms": slo.p50_ms,
        "p99_ms": slo.p99_ms,
        "mean_ms": slo.mean_ms,
        "max_ms": slo.max_ms,
        "backlog_max": slo.backlog_max,
    }
    if extra:
        row.update(extra)
    csv_row(
        f"serving/{name}",
        1e3 * slo.mean_ms,
        f"p50={slo.p50_ms:.3f}ms p99={slo.p99_ms:.3f}ms "
        f"sustained={slo.req_per_sec:.0f}/s offered={rate:.0f}/s",
    )
    # keeping up at 70% load is the point of an SLO: a server that falls
    # behind an offered rate below its measured capacity has no SLO at all
    assert slo.req_per_sec > 0.5 * rate, (name, slo.req_per_sec, rate)
    return row


def _expert_cache_slo(cfg: dict) -> dict:
    ec = OGBExpertCache(
        ExpertCacheConfig(
            n_layers=cfg["layers"],
            n_experts=cfg["experts"],
            resident_fraction=0.25,
            horizon_steps=cfg["serve_steps"],
            bytes_per_expert=64 << 20,  # a 64MB expert: swap traffic in bytes
        ),
        seed=0,
    )
    rng = np.random.default_rng(0)
    shape = (cfg["layers"], cfg["experts"])
    # pre-generated routed-count vectors: payload synthesis must not
    # pollute the decision latency
    payloads = [
        rng.poisson(5.0, shape).astype(np.float32)
        for _ in range(cfg["serve_steps"])
    ]
    for p in payloads[:20]:  # warmup: compile + residency steady-state
        ec.step(p)
    t0 = time.perf_counter()
    for p in payloads[:50]:
        ec.step(p)
    per_step = (time.perf_counter() - t0) / 50
    rate = LOAD_FACTOR / per_step

    loop = ContinuousServingLoop(lambda batch: ec.step(batch[0]))
    slo = loop.run(payloads, rate)
    swap_bytes = (ec.swapped_in + ec.swapped_out) * ec.cfg.bytes_per_expert
    return _slo_row(
        "expert_cache",
        slo,
        rate,
        extra={
            "mean_hit_ratio": ec.mean_hit_ratio,
            "swapped_in": ec.swapped_in,
            "swapped_out": ec.swapped_out,
            "swap_gb_total": swap_bytes / 1e9,
        },
    )


def _stream_window_slo(cfg: dict, n: int, c: int) -> dict:
    pd = api.policy_def("ogb")
    window = cfg["window"]
    steps = cfg["serve_steps"]
    horizon = steps * window
    rng = np.random.default_rng(1)
    zipf_p = 1.0 / np.arange(1, n + 1) ** 0.9
    zipf_p /= zipf_p.sum()
    payloads = [
        rng.choice(n, size=window, p=zipf_p).astype(np.int64)
        for _ in range(steps)
    ]

    state = {"carry": None}

    def decide(batch):
        ids = batch[0]
        if state["carry"] is None:
            res = api.run(
                pd, ids, n, c, window=window, horizon=horizon,
                track_opt=False,
            )
        else:
            res = api.run(
                pd, ids, capacity=c, carry=state["carry"], window=window,
                track_opt=False,
            )
        state["carry"] = res.carry

    for p in payloads[:10]:  # warmup: compile
        decide([p])
    t0 = time.perf_counter()
    for p in payloads[:20]:
        decide([p])
    per_step = (time.perf_counter() - t0) / 20
    rate = LOAD_FACTOR / per_step

    state["carry"] = None  # fresh policy for the measured run
    slo = ContinuousServingLoop(decide).run(payloads, rate)
    return _slo_row(
        "stream_window", slo, rate,
        extra={"requests_per_decision": window},
    )


def _async_vs_sync(
    n: int, c: int, t: int, window: int, repeats: int, min_speedup: float
):
    """run_stream prefetch=2 vs prefetch=0 on the stream_scale shape:
    bit-exact results, async throughput at or above synchronous (on hosts
    with a core to overlap into; see SINGLE_CORE_FLOOR)."""
    source = make_trace(
        "bursty", min(n, 20_000), 200_000, seed=17,
        burst_fraction=0.5, burst_len_mean=8.0, burst_span=60,
    )
    profile = fit_profile(source)
    pd = api.policy_def("ogb")

    def one(prefetch: int):
        chunks = synthesize_chunks(
            profile, t, catalog=n, seed=5, chunk_size=65_536
        )
        return run_stream(
            pd, chunks, n, c, window=window, horizon=t,
            segment_len=SEGMENT_LEN, keep_carry=False, prefetch=prefetch,
        )

    one(0)  # warmup: compile both segment shapes
    best = {}
    sample = {}
    for prefetch in (0, 2):
        walls = []
        for _ in range(repeats):
            res = one(prefetch)
            walls.append(res.wall_seconds)
            sample[prefetch] = res
        best[prefetch] = min(walls)

    # the pipeline must not change the replayed dynamics, only the clock
    np.testing.assert_array_equal(sample[0].hits, sample[2].hits)
    np.testing.assert_array_equal(sample[0].reward, sample[2].reward)

    speedup = best[0] / best[2]
    rows = {}
    for prefetch in (0, 2):
        r = sample[prefetch]
        rows[f"prefetch_{prefetch}"] = {
            "wall_seconds": best[prefetch],
            "req_per_sec": t / best[prefetch],
            "us_per_request": 1e6 * best[prefetch] / t,
            "ingest_seconds": r.ingest_seconds,
            "device_seconds": r.device_seconds,
            "host_seconds": r.host_seconds,
        }
        csv_row(
            f"serving/stream_prefetch={prefetch}",
            1e6 * best[prefetch] / t,
            f"T={t} {t / best[prefetch]:.0f}req/s "
            f"ing={r.ingest_seconds:.2f}s dev={r.device_seconds:.2f}s",
        )
    cores = os.cpu_count() or 1
    floor = min_speedup if cores > 1 else min(min_speedup, SINGLE_CORE_FLOOR)
    print(
        f"async speedup {speedup:.3f}x over synchronous at "
        f"(N={n}, C={c}, T={t}, window={window}) — floor {floor:.2f}x"
        + ("" if cores > 1 else f" (single-core host: overhead bound only)")
    )
    assert speedup >= floor, (
        f"async run_stream is slower than synchronous: {speedup:.3f}x "
        f"(best async {best[2]:.3f}s vs sync {best[0]:.3f}s, "
        f"{cores} cores, floor {floor:.2f}x)"
    )
    rows["speedup"] = speedup
    rows["cpu_count"] = cores
    rows["speedup_floor"] = floor
    return rows


def main() -> dict:
    scale_name = SCALE if SCALE in CONFIGS else "quick"
    cfg = CONFIGS[scale_name]
    stream = cfg["stream"]

    out = {
        "scale": scale_name,
        "backend": jax.default_backend(),
        "load_factor": LOAD_FACTOR,
        "slo": [
            _expert_cache_slo(cfg),
            _stream_window_slo(cfg, min(stream["n"], 20_000), stream["c"]),
        ],
        "stream": _async_vs_sync(**stream),
    }

    check_finite(out)
    save_json("serving_slo", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
