"""Baseline-engine replay throughput — the apples-to-apples speed ledger.

Replays a fig7_8-class trace (zipf 0.9, N=20k, C=N/20) of T=1e6 requests
through every registered policy engine (LRU/FIFO/LFU/FTPL automata, the OMD
mirror-descent engine, the OGB scan/tree replays, and the sized engines —
GDS on the min-pair tree and the size-aware ``ogb_sized`` tree) via the one
unified ``api.run`` path, on whatever backend JAX picks (CPU in CI).  The acceptance
bar is **< 15 us/request for every policy** — the bound that makes the
paper-scale (T=2e7) comparison runs feasible.  A short host-side LRU run is
timed for the speedup column.

Writes ``benchmarks/results/engines_throughput.json`` and the tracked
top-level ``BENCH_engines.json`` so the perf trajectory is visible PR over
PR (same pattern as ``BENCH_throughput.json``).

Also exercises the unified sweep layer: one (capacities x seeds) LRU grid
must cost close to a single replay, not |grid| replays.
"""

from __future__ import annotations

import json
import os

import numpy as np

import jax

from repro.cachesim.api import policy_def, run, sweep
from repro.cachesim.simulator import simulate
from repro.cachesim.traces import zipf
from repro.core.policies import make_policy

from .common import check_finite, csv_row, save_json, scale

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_engines.json",
)

US_PER_REQUEST_BUDGET = 15.0


def main() -> dict:
    N = 20_000
    C = N // 20
    T = scale(1_000_000, 1_000_000)  # the acceptance bar is defined at T=1e6
    B = 1000
    trace = zipf(N, T, alpha=0.9, seed=21)
    out = {
        "N": N,
        "C": C,
        "T": T,
        "backend": jax.default_backend(),
        "budget_us_per_request": US_PER_REQUEST_BUDGET,
        "engines": {},
    }

    # heterogeneous-size rows: slab sizes anti-correlated with popularity
    # (the sized_cdn regime); ogb_sized takes the equivalent byte budget
    slabs = np.asarray([1.0, 4.0, 16.0, 64.0])
    sizes = slabs[np.minimum(np.arange(N) * len(slabs) // N, len(slabs) - 1)]
    cap_bytes = int(round(C * float(sizes.mean())))

    for kind in (
        "lru", "fifo", "lfu", "ftpl", "omd", "ogb", "ogb_tree",
        "gds", "ogb_sized",
    ):
        pd = policy_def(kind)
        sized = kind in ("gds", "ogb_sized")
        window = B if pd.fractional else max(T // 100, 1)
        r = run(
            pd, trace, N, cap_bytes if kind == "ogb_sized" else C,
            window=window, horizon=T, track_opt=False,
            sizes=sizes if sized else None,
        )
        out["engines"][r.name] = {
            "us_per_request": r.us_per_request,
            "hit_ratio": r.hit_ratio,
        }
        if sized:
            out["engines"][r.name]["byte_hit_ratio"] = r.byte_hit_ratio
        csv_row(
            f"engines/{r.name}", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}"
        )

    # host-side reference point (short run; the engines replace this loop)
    t_host = min(T, 100_000)
    host = simulate(make_policy("lru", N, C), trace[:t_host], record_cum=False)
    out["host_lru_us_per_request"] = host.us_per_request
    out["lru_speedup_vs_host"] = (
        host.us_per_request / out["engines"]["LRU"]["us_per_request"]
    )
    csv_row("engines/host_LRU", host.us_per_request, f"T={t_host}")
    # the prefix-tree LRU engine must beat the host loop outright — a
    # regression below 1x means the O(log) reuse-distance path broke
    assert out["lru_speedup_vs_host"] >= 1.0, out["lru_speedup_vs_host"]

    # vmapped sweep amortization: a 6-combo LRU grid in one dispatch
    sweep_t = min(T, 200_000)
    sw = sweep(
        policy_def("lru"),
        trace[:sweep_t],
        N,
        capacities=[C // 4, C // 2, C],
        seeds=(0, 1),
        window=max(sweep_t // 20, 1),
        track_opt=False,
    )
    single = run(
        policy_def("lru"), trace[:sweep_t], N, C,
        window=max(sweep_t // 20, 1), track_opt=False,
    )
    out["sweep"] = {
        "combos": len(sw.combos),
        "us_per_request_total": 1e6 * sw.wall_seconds / sw.T,
        "amortization_vs_serial": (
            len(sw.combos)
            * single.wall_seconds
            / max(sw.wall_seconds, 1e-12)
        ),
        "hit_ratios": {
            f"C={c['capacity']}/seed={c['seed']}": float(h)
            for c, h in zip(sw.combos, sw.hit_ratios)
        },
    }
    print(
        f"sweep: {len(sw.combos)} combos in {sw.wall_seconds:.2f}s "
        f"({out['sweep']['amortization_vs_serial']:.2f}x vs serial replays)"
    )

    for name, row in out["engines"].items():
        print(
            f"{name:>6}: {row['us_per_request']:8.3f} us/req   "
            f"hit={row['hit_ratio']:.4f}"
        )
        assert row["us_per_request"] < US_PER_REQUEST_BUDGET, (
            name,
            row["us_per_request"],
        )
    check_finite(out)
    save_json("engines_throughput", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
