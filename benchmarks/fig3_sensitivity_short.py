"""Paper Fig. 3 — parameter sensitivity on a short real-world-like trace.

OGB is robust to eta over orders of magnitude; FTPL is brittle in zeta.
Trace: cdn-like Zipf, subsampled scale (1e5 requests, 1e4 items, C=500)."""

from __future__ import annotations


from repro.cachesim.simulator import simulate
from repro.cachesim.traces import zipf
from repro.core.ftpl import FTPL, theoretical_zeta
from repro.core.ogb import OGB, theoretical_eta

from .common import csv_row, save_json, scale


def main() -> dict:
    N, C = scale((3000, 150), (10_000, 500))
    T = scale(30_000, 100_000)
    trace = zipf(N, T, alpha=0.8, seed=1)

    eta0 = theoretical_eta(C, N, T)
    zeta0 = theoretical_zeta(C, N, T)
    factors = [0.1, 0.5, 1.0, 5.0, 10.0]

    ogb_rows, ftpl_rows = {}, {}
    for f in factors:
        r = simulate(OGB(N, C, eta=eta0 * f), trace, window=T, record_cum=False)
        ogb_rows[f] = r.hit_ratio
        csv_row(f"fig3/OGB_eta_x{f}", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}")
    for f in factors:
        r = simulate(FTPL(N, C, zeta=zeta0 * f), trace, window=T, record_cum=False)
        ftpl_rows[f] = r.hit_ratio
        csv_row(f"fig3/FTPL_zeta_x{f}", r.us_per_request, f"hit_ratio={r.hit_ratio:.4f}")

    ogb_spread = max(ogb_rows.values()) - min(ogb_rows.values())
    ftpl_spread = max(ftpl_rows.values()) - min(ftpl_rows.values())
    print(f"\nFig3 sensitivity (N={N} C={C} T={T}):")
    print(f"  OGB  hit ratio across eta x[0.1..10]:  {ogb_rows}  spread={ogb_spread:.4f}")
    print(f"  FTPL hit ratio across zeta x[0.1..10]: {ftpl_rows} spread={ftpl_spread:.4f}")
    assert ogb_spread < ftpl_spread + 0.02, "OGB should be the more robust one"
    save_json(
        "fig3_sensitivity_short",
        {"ogb": ogb_rows, "ftpl": ftpl_rows, "eta0": eta0, "zeta0": zeta0},
    )
    return {"ogb": ogb_rows, "ftpl": ftpl_rows}


if __name__ == "__main__":
    main()
