"""Paper Fig. 11 / §B.2 — temporal-locality analysis of the trace families.

Left: cumulative max-hit share of items sorted by lifetime — the twitter-like
trace gets ~20% of its attainable hits from items with lifetime < 100
requests; the cdn-like trace gets almost none from short-lived items.
Right: reuse-distance CDF (twitter-like concentrated at small distances).

Configured through the scenario registry (``fig11_cdn`` / ``fig11_twitter``)
and computed with the vectorized ``trace_stats`` / ``reuse_distances`` — the
per-request Python dict loops are gone, so REPRO_BENCH_SCALE=full analyses
the paper's T=2e7 traces in seconds."""

from __future__ import annotations

import numpy as np

from repro.cachesim.scenarios import get_scenario
from repro.cachesim.traces import reuse_distances, trace_stats

from .common import SCALE, check_finite, csv_row, save_json


def main() -> dict:
    scale = "full" if SCALE == "full" else "quick"
    out = {}
    for tname, sname in {
        "cdn_like": "fig11_cdn",
        "twitter_like": "fig11_twitter",
    }.items():
        sc = get_scenario(sname)
        trace = sc.make_trace(scale)
        st = trace_stats(trace)
        share100 = st.hit_share_lifetime_below(100)
        share1k = st.hit_share_lifetime_below(1000)
        rd = reuse_distances(trace)
        med_rd = float(np.median(rd)) if len(rd) else float("nan")
        frac_rd_small = float(np.mean(rd < 100)) if len(rd) else 0.0
        out[tname] = {
            "hit_share_lifetime_lt_100": share100,
            "hit_share_lifetime_lt_1000": share1k,
            "median_reuse_distance": med_rd,
            "frac_reuse_lt_100": frac_rd_small,
            "unique_items": st.unique,
        }
        csv_row(
            f"fig11/{tname}",
            0.0,
            f"share_lt100={share100:.3f};median_rd={med_rd:.0f}",
        )
        print(
            f"{tname}: hit share from items w/ lifetime<100: {share100:.3f}, "
            f"<1000: {share1k:.3f}; median reuse dist {med_rd:.0f}; "
            f"frac reuse<100: {frac_rd_small:.3f}"
        )
    # generator calibration vs the paper's analysis: twitter-like gets a
    # large hit share from short-lived items, cdn-like essentially none and
    # its items are re-requested throughout (large reuse distances)
    assert out["twitter_like"]["hit_share_lifetime_lt_100"] > 0.08
    assert out["cdn_like"]["hit_share_lifetime_lt_100"] < 0.05
    assert out["twitter_like"]["frac_reuse_lt_100"] > 0.10
    assert out["cdn_like"]["median_reuse_distance"] > 500
    check_finite(out)
    save_json("fig11_locality", out)
    return out


if __name__ == "__main__":
    main()
