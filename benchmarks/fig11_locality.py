"""Paper Fig. 11 / §B.2 — temporal-locality analysis of the trace families.

Left: cumulative max-hit share of items sorted by lifetime — the twitter-like
trace gets ~20% of its attainable hits from items with lifetime < 100
requests; the cdn-like trace gets almost none from short-lived items.
Right: reuse-distance CDF (twitter-like concentrated at small distances)."""

from __future__ import annotations

import numpy as np

from repro.cachesim.traces import bursty, reuse_distances, trace_stats, zipf

from .common import csv_row, save_json, scale


def main() -> dict:
    N = scale(20_000, 1_000_000)
    T = scale(150_000, 20_000_000)
    out = {}
    for tname, trace in {
        "cdn_like": zipf(N, T, alpha=0.9, seed=11),
        "twitter_like": bursty(N, T, seed=12),
    }.items():
        st = trace_stats(trace)
        share100 = st.hit_share_lifetime_below(100)
        share1k = st.hit_share_lifetime_below(1000)
        rd = reuse_distances(trace)
        med_rd = float(np.median(rd)) if len(rd) else float("nan")
        frac_rd_small = float(np.mean(rd < 100)) if len(rd) else 0.0
        out[tname] = {
            "hit_share_lifetime_lt_100": share100,
            "hit_share_lifetime_lt_1000": share1k,
            "median_reuse_distance": med_rd,
            "frac_reuse_lt_100": frac_rd_small,
            "unique_items": st.unique,
        }
        csv_row(
            f"fig11/{tname}",
            0.0,
            f"share_lt100={share100:.3f};median_rd={med_rd:.0f}",
        )
        print(
            f"{tname}: hit share from items w/ lifetime<100: {share100:.3f}, "
            f"<1000: {share1k:.3f}; median reuse dist {med_rd:.0f}; "
            f"frac reuse<100: {frac_rd_small:.3f}"
        )
    # generator calibration vs the paper's analysis: twitter-like gets a
    # large hit share from short-lived items, cdn-like essentially none and
    # its items are re-requested throughout (large reuse distances)
    assert out["twitter_like"]["hit_share_lifetime_lt_100"] > 0.08
    assert out["cdn_like"]["hit_share_lifetime_lt_100"] < 0.05
    assert out["twitter_like"]["frac_reuse_lt_100"] > 0.10
    assert out["cdn_like"]["median_reuse_distance"] > 500
    save_json("fig11_locality", out)
    return out


if __name__ == "__main__":
    main()
