"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints a compact CSV (name,us_per_call,derived) plus a
human-readable table, and writes JSON to benchmarks/results/.  Default sizes
run in minutes on one CPU core; set REPRO_BENCH_SCALE=full for paper-scale
runs (millions of requests / items).
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")


def scale(quick, full):
    return full if SCALE == "full" else quick


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def make_policies(N, C, T, B=1, eta=None, zeta=None, seed=0):
    """The paper's comparison set, tuned per theory unless overridden."""
    from repro.core.ftpl import FTPL
    from repro.core.ogb import OGB
    from repro.core.policies import ARC, LFU, LRU

    return {
        "OGB": OGB(N, C, eta=eta, horizon=None if eta else T, batch_size=B, seed=seed),
        "FTPL": FTPL(N, C, zeta=zeta, horizon=None if zeta else T, seed=seed),
        "LRU": LRU(N, C),
        "LFU": LFU(N, C),
        "ARC": ARC(N, C),
    }
