"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints a compact CSV (name,us_per_call,derived) plus a
human-readable table, and writes JSON to benchmarks/results/.  Default sizes
run in minutes on one CPU core; set REPRO_BENCH_SCALE=full for paper-scale
runs (millions of requests / items).
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")


def scale(quick, full):
    return full if SCALE == "full" else quick


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def make_policies(N, C, T, B=1, eta=None, zeta=None, seed=0, kinds=None):
    """The paper's host-side comparison set, tuned per theory unless
    overridden.  Every constructor goes through the one shared registry
    (:data:`repro.core.policies.POLICY_REGISTRY`) so the kind-string set
    cannot drift from ``make_policy`` / ``simulator.compare``.
    """
    from repro.core.policies import make_policy

    per_kind_kw = {
        "ogb": dict(eta=eta, horizon=None if eta else T, batch_size=B, seed=seed),
        "ogb_cl": dict(eta=eta, horizon=None if eta else T, batch_size=B, seed=seed),
        "omd_cl": dict(eta=eta, horizon=None if eta else T, batch_size=B, seed=seed),
        "ftpl": dict(zeta=zeta, horizon=None if zeta else T, seed=seed),
    }
    out = {}
    if kinds is None:
        kinds = ("ogb", "ftpl", "lru", "lfu", "arc")
    for kind in kinds:
        p = make_policy(kind, N, C, **per_kind_kw.get(kind, {}))
        out[getattr(p, "name", kind)] = p
    return out


def check_finite(payload, _path="results") -> None:
    """Fail a benchmark loudly on NaN/inf/empty/missing results (CI guard)."""
    if isinstance(payload, dict):
        if not payload:
            raise AssertionError(f"{_path}: empty result dict")
        for k, v in payload.items():
            check_finite(v, f"{_path}.{k}")
    elif isinstance(payload, (list, tuple)):
        if not payload:
            raise AssertionError(f"{_path}: empty result list")
        for i, v in enumerate(payload):
            check_finite(v, f"{_path}[{i}]")
    elif isinstance(payload, np.ndarray):
        if payload.size == 0:
            raise AssertionError(f"{_path}: empty result array")
        if np.issubdtype(payload.dtype, np.number) and not np.all(
            np.isfinite(payload)
        ):
            raise AssertionError(f"{_path}: non-finite values {payload!r}")
    elif isinstance(payload, (bool, str)):
        pass  # labels / flags are fine
    elif isinstance(payload, (int, float, np.floating, np.integer)):
        if not np.isfinite(payload):
            raise AssertionError(f"{_path}: non-finite value {payload!r}")
    else:
        # None (the canonical missing-result value) and anything exotic:
        # a guard that shrugs at these would write the bad JSON anyway
        raise AssertionError(
            f"{_path}: unexpected result type {type(payload).__name__}"
        )
