"""The paper's headline claim: O(log N) amortized per-request complexity.

Wall-clock per request vs catalog size for OGB (lazy, O(log N)) against
OGB_cl (eager projection, Theta(N log N) per request at B=1) and the O(1)/
O(log C) classics.  OGB's curve must stay ~flat in N while OGB_cl blows up —
the reason prior no-regret evaluations stopped at 10^4 items (paper Fig. 1).
"""

from __future__ import annotations

import time

import numpy as np

from repro.cachesim.api import policy_def, run as api_run
from repro.cachesim.traces import zipf
from repro.core.ftpl import FTPL
from repro.core.ogb import OGB
from repro.core.ogb_classic import OGBClassic
from repro.core.policies import LRU

from .common import csv_row, save_json, scale


def main() -> dict:
    sizes = scale([10_000, 100_000, 1_000_000], [10_000, 100_000, 1_000_000, 10_000_000])
    T = scale(50_000, 200_000)
    T_cl = scale(300, 1000)  # OGB_cl is too slow for full T at large N
    B_scan = 1000  # the batched data-plane operating point
    out = {}
    for N in sizes:
        C = N // 20
        trace = zipf(N, T, alpha=0.8, seed=13)
        row = {}
        for name, policy, t_use in [
            ("OGB", OGB(N, C, horizon=T), T),
            ("FTPL", FTPL(N, C, horizon=T), T),
            ("LRU", LRU(N, C), T),
            ("OGB_cl", OGBClassic(N, C, horizon=T), T_cl),
        ]:
            t0 = time.perf_counter()
            for j in trace[:t_use]:
                policy.request(int(j))
            us = 1e6 * (time.perf_counter() - t0) / t_use
            row[name] = us
            csv_row(f"complexity/N={N}/{name}", us, f"C={C}")
        # the scan-compiled batched data plane (B=1000); api.run compiles
        # ahead of time, so the measured wall is the steady-state replay
        m = api_run(
            policy_def("ogb"), trace, N, C, window=B_scan, seed=13,
            track_opt=False,
        )
        row["OGB_scan_B1000"] = m.us_per_request
        csv_row(f"complexity/N={N}/OGB_scan_B1000", m.us_per_request, f"C={C}")
        out[N] = row
        print(
            f"N={N:>10,}: "
            + "  ".join(f"{k}={v:9.2f}us" for k, v in row.items())
        )

    # O(log N): 100x catalog growth must cost < 4x per-request time for OGB
    ns = sorted(out)
    growth_ogb = out[ns[-1]]["OGB"] / out[ns[0]]["OGB"]
    growth_cl = out[ns[-1]]["OGB_cl"] / max(out[ns[0]]["OGB_cl"], 1e-9)
    print(f"\nOGB growth over {ns[-1]//ns[0]}x catalog: {growth_ogb:.2f}x "
          f"(OGB_cl: {growth_cl:.1f}x)")
    assert growth_ogb < 5.0
    assert growth_cl > 10.0
    save_json("complexity_scaling", out)
    return out


if __name__ == "__main__":
    main()
