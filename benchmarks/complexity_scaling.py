"""The paper's headline claim: O(log N) amortized per-request complexity.

Wall-clock per request vs catalog size for OGB (lazy, O(log N)) against
OGB_cl (eager projection, Theta(N log N) per request at B=1) and the O(1)/
O(log C) classics.  OGB's curve must stay ~flat in N while OGB_cl blows up —
the reason prior no-regret evaluations stopped at 10^4 items (paper Fig. 1).

The device section replays the same claim through the compiled engines:
``ogb`` (dense per-chunk projection, O(N) per chunk), ``ogb_tree`` (the lazy
bucketized projection over prefix trees, O(B log V) per chunk — per-request
cost independent of N) and the prefix-tree ``lru`` automaton.  Per-engine
power-law exponents ``us/req ~ N^p`` are fitted in log-log space and written
to the tracked ``BENCH_complexity.json``; the lazy tree engine must stay
sublinear (p << 1) while the dense scan grows toward linear.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.cachesim.api import policy_def, run as api_run
from repro.cachesim.traces import zipf
from repro.core.ftpl import FTPL
from repro.core.ogb import OGB
from repro.core.ogb_classic import OGBClassic
from repro.core.policies import LRU

from .common import csv_row, save_json, scale

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_complexity.json",
)

#: device engines swept over N (name -> policy_def kwargs; ``sized=True``
#: marks engines that take the slab size array and a byte capacity)
DEVICE_ENGINES = {
    "ogb_scan": dict(kind="ogb"),
    "ogb_tree": dict(kind="ogb_tree"),
    "lru_tree": dict(kind="lru"),
    "ogb_sized_tree": dict(kind="ogb_sized", flavor="tree", sized=True),
}

#: slab sizes for the sized engines (4 distinct values -> 4 exact size
#: classes), anti-correlated with popularity like the sized_cdn scenario
SIZE_SLABS = np.asarray([1.0, 4.0, 16.0, 64.0])


def _slab_sizes(n: int) -> np.ndarray:
    k = len(SIZE_SLABS)
    return SIZE_SLABS[np.minimum(np.arange(n) * k // n, k - 1)]


def fit_exponent(sizes, us):
    """Least-squares slope of log(us) vs log(N): us ~ N^p.  p ~ 0 is flat
    (per-request cost independent of the catalog), p ~ 1 is linear."""
    x = np.log(np.asarray(sizes, np.float64))
    y = np.log(np.maximum(np.asarray(us, np.float64), 1e-9))
    p, _ = np.polyfit(x, y, 1)
    return float(p)


def main() -> dict:
    sizes = scale([10_000, 100_000, 1_000_000], [10_000, 100_000, 1_000_000, 10_000_000])
    T = scale(50_000, 200_000)
    T_cl = scale(300, 1000)  # OGB_cl is too slow for full T at large N
    B_scan = 1000  # the batched data-plane operating point
    out = {}
    device = {name: {} for name in DEVICE_ENGINES}
    for N in sizes:
        C = N // 20
        trace = zipf(N, T, alpha=0.8, seed=13)
        row = {}
        for name, policy, t_use in [
            ("OGB", OGB(N, C, horizon=T), T),
            ("FTPL", FTPL(N, C, horizon=T), T),
            ("LRU", LRU(N, C), T),
            ("OGB_cl", OGBClassic(N, C, horizon=T), T_cl),
        ]:
            t0 = time.perf_counter()
            for j in trace[:t_use]:
                policy.request(int(j))
            us = 1e6 * (time.perf_counter() - t0) / t_use
            row[name] = us
            csv_row(f"complexity/N={N}/{name}", us, f"C={C}")
        # the scan-compiled batched data plane (B=1000); api.run compiles
        # ahead of time, so the measured wall is the steady-state replay
        for name, kw in DEVICE_ENGINES.items():
            kw = dict(kw)
            sized = kw.pop("sized", False)
            pd = policy_def(kw.pop("kind"), **kw)
            sizes = _slab_sizes(N) if sized else None
            cap = (
                int(round(C * float(sizes.mean()))) if sized else C
            )
            m = api_run(
                pd, trace, N, cap, window=B_scan, seed=13, track_opt=False,
                keep_carry=False, sizes=sizes,
            )
            device[name][N] = m.us_per_request
            row[name] = m.us_per_request
            csv_row(f"complexity/N={N}/{name}", m.us_per_request, f"C={C}")
        out[N] = row
        print(
            f"N={N:>10,}: "
            + "  ".join(f"{k}={v:9.2f}us" for k, v in row.items())
        )

    # O(log N): 100x catalog growth must cost < 4x per-request time for OGB
    ns = sorted(out)
    growth_ogb = out[ns[-1]]["OGB"] / out[ns[0]]["OGB"]
    growth_cl = out[ns[-1]]["OGB_cl"] / max(out[ns[0]]["OGB_cl"], 1e-9)
    print(f"\nOGB growth over {ns[-1]//ns[0]}x catalog: {growth_ogb:.2f}x "
          f"(OGB_cl: {growth_cl:.1f}x)")
    assert growth_ogb < 5.0
    assert growth_cl > 10.0

    # device engines: fitted power-law exponents (slope vs linear p=1)
    exponents = {
        name: fit_exponent(ns, [vals[N] for N in ns])
        for name, vals in device.items()
    }
    for name, p in exponents.items():
        print(f"device {name}: us/req ~ N^{p:.3f} "
              f"({'sublinear' if p < 0.5 else 'NOT sublinear'})")
    # the tentpole claim: the lazy tree projection's per-request cost must
    # stay far from linear in the catalog size — for the unit engine AND
    # its K-size-class weighted generalization
    assert exponents["ogb_tree"] < 0.5, exponents
    assert exponents["lru_tree"] < 0.5, exponents
    assert exponents["ogb_sized_tree"] < 0.5, exponents

    bench = {
        "sizes": [int(n) for n in ns],
        "T": T,
        "window": B_scan,
        "device_us_per_request": {
            name: {str(N): vals[N] for N in ns}
            for name, vals in device.items()
        },
        "power_law_exponent": exponents,
        "slope_ratio_vs_linear": {k: v / 1.0 for k, v in exponents.items()},
        "host_us_per_request": {
            str(N): {k: v for k, v in row.items() if k not in DEVICE_ENGINES}
            for N, row in out.items()
        },
    }
    save_json("complexity_scaling", out)
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
