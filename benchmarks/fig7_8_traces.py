"""Paper Figs. 7 & 8 — windowed hit ratio on the four trace families.

ms-ex-like (shifting zipf), systor-like (scan mix), cdn-like (stationary
zipf: OPT >> LRU, no-regret policies approach OPT), twitter-like (bursty:
LRU wins; OGB robust; FTPL ~ noisy LFU)."""

from __future__ import annotations

import numpy as np

from repro.cachesim.simulator import simulate
from repro.cachesim.traces import bursty, scan_mix, shifting_zipf, zipf
from repro.core.regret import opt_windowed_hit_ratio

from .common import csv_row, make_policies, save_json, scale


TRACES = {
    "ms_ex_like": lambda N, T: shifting_zipf(N, T, alpha=0.9, phase=max(T // 8, 1), seed=3),
    "systor_like": lambda N, T: scan_mix(N, T, seed=4),
    "cdn_like": lambda N, T: zipf(N, T, alpha=0.9, seed=5),
    "twitter_like": lambda N, T: bursty(
        N, T, burst_fraction=0.5, burst_len_mean=8.0, burst_span=60, seed=6
    ),
}


def main() -> dict:
    N = scale(20_000, 1_000_000)
    T = scale(200_000, 20_000_000)
    C = N // 20
    window = max(T // 10, 1)

    results = {}
    for tname, gen in TRACES.items():
        trace = gen(N, T)
        policies = make_policies(N, C, T)
        rows = {}
        for pname, p in policies.items():
            res = simulate(p, trace, window=window, record_cum=False)
            rows[pname] = res.hit_ratio
            csv_row(
                f"fig7_8/{tname}/{pname}",
                res.us_per_request,
                f"hit_ratio={res.hit_ratio:.4f}",
            )
        opt_w = opt_windowed_hit_ratio(trace, C, window)
        rows["OPT(static)"] = float(np.mean(opt_w))
        results[tname] = rows
        print(f"\n{tname} (N={N} C={C} T={T}):")
        for k, v in sorted(rows.items(), key=lambda kv: -kv[1]):
            print(f"  {k:>12}: hit={v:.4f}")

    # figure-level claims
    assert results["cdn_like"]["OGB"] > results["cdn_like"]["LRU"], "Fig8-left"
    # Fig8-right: temporal locality lets recency policies beat the static
    # allocation (paper: LRU highest; our ARC variant is the recency leader)
    recency_best = max(results["twitter_like"]["LRU"], results["twitter_like"]["ARC"])
    assert recency_best > results["twitter_like"]["OPT(static)"], results["twitter_like"]
    save_json("fig7_8_traces", results)
    return results


if __name__ == "__main__":
    main()
