"""Paper Figs. 7 & 8 — windowed hit ratio on the four trace families.

ms-ex-like (shifting zipf), systor-like (scan mix), cdn-like (stationary
zipf: OPT >> LRU, no-regret policies approach OPT), twitter-like (bursty:
LRU wins; OGB robust; FTPL ~ noisy LFU).

Migrated onto the device-resident engines via the scenario registry: every
baseline (LRU/LFU/FIFO/FTPL/OMD/OGB) is one compiled ``lax.scan``, so
REPRO_BENCH_SCALE=full replays the paper's T=2e7 traces in minutes instead of
hours; ARC stays on the host-side oracle path and is skipped automatically at
full scale."""

from __future__ import annotations

import numpy as np

from repro.cachesim.scenarios import get_scenario, run_scenario
from repro.core.regret import opt_windowed_hit_ratio

from .common import SCALE, check_finite, csv_row, save_json

SCENARIO_NAMES = {
    "ms_ex_like": "fig7_ms_ex",
    "systor_like": "fig7_systor",
    "cdn_like": "fig8_cdn",
    "twitter_like": "fig8_twitter",
}


def main() -> dict:
    scale = "full" if SCALE == "full" else "quick"
    results = {}
    for tname, sname in SCENARIO_NAMES.items():
        sc = get_scenario(sname)
        N, T, C = sc.dims(scale)
        window = max(T // 10, 1)
        trace = sc.make_trace(scale)
        # OPT is recomputed windowed below — skip the scenario's own OPT pass
        res = run_scenario(sname, scale=scale, trace=trace, include_opt=False)
        rows = {name: row["hit_ratio"] for name, row in res.rows.items()}
        for pname, row in res.rows.items():
            csv_row(
                f"fig7_8/{tname}/{pname}",
                row.get("us_per_request", 0.0),
                f"hit_ratio={row['hit_ratio']:.4f}",
            )
        opt_w = opt_windowed_hit_ratio(trace, C, window)
        rows["OPT(static)"] = float(np.mean(opt_w))
        results[tname] = rows
        print(f"\n{tname} (N={N} C={C} T={T}):")
        for k, v in sorted(rows.items(), key=lambda kv: -kv[1]):
            print(f"  {k:>12}: hit={v:.4f}")
        if res.skipped:
            print(f"  (host-only policies skipped at this scale: {res.skipped})")

    # figure-level claims
    assert results["cdn_like"]["OGB"] > results["cdn_like"]["LRU"], "Fig8-left"
    # Fig8-right: temporal locality lets recency policies beat the static
    # allocation (paper: LRU highest among the recency family)
    recency_best = max(
        results["twitter_like"]["LRU"],
        results["twitter_like"].get("ARC", 0.0),
    )
    assert recency_best > results["twitter_like"]["OPT(static)"], results[
        "twitter_like"
    ]
    check_finite(results)
    save_json("fig7_8_traces", results)
    return results


if __name__ == "__main__":
    main()
