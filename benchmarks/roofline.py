"""§Roofline — three-term roofline per (arch x shape) cell.

Terms (per chip, seconds), TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI):

  compute    = FLOPs_per_chip / 197e12
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9

Sources — and an important measurement note.  XLA's ``cost_analysis()`` counts
a ``while`` body ONCE, but our layer stacks are lax.scan loops (the body runs
L times), so raw cost_analysis under-counts by ~L.  We therefore use:

  * collective bytes: parsed from the optimized HLO with while-trip-count
    correction (repro.launch.hlo_analysis) — fully derived from the compiled
    artifact;
  * FLOPs and HBM bytes: explicit analytic models (formulas below), because
    the aggregate cost numbers cannot be trip-count-corrected post hoc.  Raw
    cost_analysis values are still recorded in the dry-run JSONs as
    structural evidence.

FLOPs model (global, divided by chip count):
  matmul  = k * N_matmul * tokens           k = 6 train (fwd+bwd), 2 inference
  remat   = x4/3 on train matmul+attention  (one extra forward)
  attn    = 6|2 * B*S^2*H*hd per full-attention layer (causal half included)
  decode attn = 4 * B*S_kv*H*hd per layer
  rwkv    = 8 * D*hd_rwkv per token/layer; mamba = 6*d_in*n + 2*W*d_in
MoE overcompute from the capacity factor is reported via useful_flops_ratio.

HBM model (per chip):
  params:   P_shard * (4B read + 8B opt traffic [f32] | 4B [bf16 moments])
            for train; P_shard * 2B read for inference
  KV cache: full read (+write of 1 token) for decode; write for prefill
  acts:     tokens_chip * L * (12 D + 6 F_active) * 2B * (3 train | 1 inf)
  logits:   2 * tokens_chip * V_pad/model_shards * 4B
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs.base import SHAPES, get_arch

from .common import RESULTS_DIR, save_json

PEAK = 197e12
HBM = 819e9
ICI = 50e9

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")
VOCAB_PAD = 256
FSDP_THRESHOLD = 2.0e10


def _n_attn_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    n = cfg.n_layers // cfg.attn_period if cfg.attn_period else cfg.n_layers
    if cfg.family == "encdec":
        n += cfg.n_encoder_layers + cfg.n_layers  # self-enc + cross
    return n


def analytic_flops(cfg, shp) -> Dict[str, float]:
    pv = -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD
    n_matmul = cfg.active_param_count() - pv * cfg.d_model  # embed gather is free
    B, S = shp.global_batch, shp.seq_len
    train = shp.kind == "train"
    k = 6.0 if train else 2.0
    tokens = B * S if shp.kind != "decode" else B

    matmul = k * n_matmul * tokens

    attn = 0.0
    if cfg.n_heads:
        hhd = cfg.n_heads * cfg.head_dim
        la = _n_attn_layers(cfg)
        if shp.kind == "decode":
            attn = 4.0 * B * S * hhd * la
        else:
            attn = k * B * (S ** 2) * hhd * la / 2.0  # causal half

    rec = 0.0
    if cfg.family == "ssm":
        rec = 8.0 * cfg.d_model * cfg.rwkv_head_dim * tokens * cfg.n_layers
        rec *= 3.0 if train else 1.0
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        n_mamba = cfg.n_layers - cfg.n_layers // cfg.attn_period
        rec = (6.0 * d_in * cfg.ssm_state_dim + 2.0 * cfg.ssm_conv_width * d_in)
        rec *= tokens * n_mamba * (3.0 if train else 1.0)

    remat = 4.0 / 3.0 if (train and cfg.remat) else 1.0
    total = (matmul + attn) * remat + rec
    return {"total": total, "matmul": matmul, "attn": attn, "recurrent": rec}


def analytic_hbm_bytes(cfg, shp, n_dev: int, model_shards: int = 16) -> float:
    pv = -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD
    P = cfg.param_count()
    fsdp = P > FSDP_THRESHOLD
    p_shard = P / (model_shards * (n_dev // model_shards if fsdp else 1))
    B, S = shp.global_batch, shp.seq_len
    train = shp.kind == "train"
    tokens_chip = (B * S if shp.kind != "decode" else B) / n_dev

    if train:
        mom_bytes = 2 if P > FSDP_THRESHOLD else 4
        param_traffic = p_shard * (4 + 4 + 4 * mom_bytes)  # read+write + m,v RW
    else:
        param_traffic = p_shard * 2

    kv = 0.0
    if cfg.n_kv_heads:
        la = cfg.n_layers // cfg.attn_period if cfg.attn_period else cfg.n_layers
        # §Perf H3: int8 KV stores 1B/elem + one f32 scale per (token, head)
        kv_elem_bytes = (
            1.0 + 4.0 / cfg.head_dim if cfg.kv_cache_dtype == "int8" else 2.0
        )
        kv_total = 2.0 * la * B * S * cfg.n_kv_heads * cfg.head_dim * kv_elem_bytes
        if shp.kind == "decode":
            kv = kv_total / n_dev  # full read of the sharded cache
        elif shp.kind == "prefill":
            kv = kv_total / n_dev  # write once

    f_active = cfg.expert_ff * cfg.experts_per_token if cfg.n_experts else cfg.d_ff
    acts = tokens_chip * cfg.n_layers * (12 * cfg.d_model + 6 * f_active) * 2
    acts *= 3.0 if train else 1.0

    logits = 2.0 * tokens_chip * (pv / model_shards) * 4
    if shp.kind == "decode":
        logits = 2.0 * tokens_chip * (pv / model_shards) * 4

    return param_traffic + kv + acts + logits


def load_cells(mesh_kind: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh_kind}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze(mesh_kind: str = "single") -> List[Dict]:
    rows = []
    for r in load_cells(mesh_kind):
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "ok": False,
                         "error": r.get("error", "")[:120]})
            continue
        if r["arch"] == "ogb-cache-dataplane":
            # the paper-technique cell: HLO terms are exact here (one psum
            # per bisection iteration, no layer scan inside)
            n_dev = r["n_devices"]
            t_comp = (r.get("flops") or 0) / PEAK
            t_mem = (r.get("bytes_accessed") or 0) / HBM
            t_coll = (r.get("collective_bytes_corrected_total") or 0) / ICI
            terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "ok": True,
                "n_devices": n_dev,
                "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
                "dominant": max(terms, key=terms.get),
                "roofline_fraction": t_comp / max(max(terms.values()), 1e-30),
                "useful_flops_ratio": 1.0,
                "model_flops": r.get("flops"),
                "hbm_bytes_chip": r.get("bytes_accessed"),
                "collective_bytes_chip": r.get("collective_bytes_corrected_total"),
                "temp_bytes_gib": (r.get("temp_size_bytes") or 0) / 2**30,
                "fits_hbm16": True,
                "compile_s": r.get("compile_s"),
            })
            continue
        cfg = get_arch(r["arch"])
        shp = SHAPES[r["shape"]]
        n_dev = r["n_devices"]

        fl = analytic_flops(cfg, shp)
        t_comp = fl["total"] / n_dev / PEAK
        hbm = analytic_hbm_bytes(cfg, shp, n_dev)
        t_mem = hbm / HBM
        coll = r.get("collective_bytes_corrected_total")
        if coll is None:
            coll = r.get("collective_bytes_total", 0.0)
        t_coll = coll / ICI

        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = t_comp / bound if bound > 0 else float("nan")
        # useful ratio: model matmul+attn flops without remat vs total issued
        useful = (fl["matmul"] + fl["attn"] + fl["recurrent"]) / max(fl["total"], 1)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "ok": True,
            "n_devices": n_dev,
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            # serialized vs perfectly-overlapped step bounds: the ratio is the
            # headroom available to async-collective scheduling (time-side
            # lever; the §Perf campaign attacks the byte-side)
            "step_serial_s": sum(terms.values()),
            "step_overlapped_s": bound,
            "overlap_headroom": sum(terms.values()) / bound if bound > 0 else 1.0,
            "dominant": dom, "roofline_fraction": frac,
            "useful_flops_ratio": useful,
            "model_flops": fl["total"],
            "hbm_bytes_chip": hbm,
            "collective_bytes_chip": coll,
            "hlo_flops_raw": r.get("flops"),
            "hlo_bytes_raw": r.get("bytes_accessed"),
            "temp_bytes_gib": (r.get("temp_size_bytes") or 0) / 2**30,
            "fits_hbm16": ((r.get("temp_size_bytes") or 0)
                           + (r.get("argument_size_bytes") or 0)) < 16 * 2**30,
            "compile_s": r.get("compile_s"),
        })
    return rows


def recommendation(row: Dict) -> str:
    if not row.get("ok"):
        return "fix the failure first"
    d = row["dominant"]
    if d == "collective":
        return ("cut collective bytes: shard the MoE dispatch buffer over data, "
                "all-to-all instead of all-gather, overlap with compute")
    if d == "memory":
        return ("raise arithmetic intensity: fuse projection+bisection sweeps "
                "(Pallas), bf16 optimizer I/O, bigger per-chip batch")
    return "near compute bound: overlap the remaining collectives; tune tiles"


def render_markdown(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | roofline frac | fits 16G | bottleneck fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED {r['error']} | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.3f} | {'y' if r['fits_hbm16'] else 'n'} "
            f"| {recommendation(r)[:60]} |"
        )
    return "\n".join(out)


def main() -> List[Dict]:
    rows = analyze("single")
    print(render_markdown(rows))
    ok = [r for r in rows if r.get("ok")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" ({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:  {coll['arch']}/{coll['shape']}"
              f" ({coll['collective_s']:.3e}s)")
    save_json("roofline_single", rows)
    save_json("roofline_multi", analyze("multi"))
    return rows


if __name__ == "__main__":
    main()
