"""Heterogeneous object sizes: the sized_cdn scenario's byte-vs-object split.

Runs the ``sized_cdn`` scenario (zipf popularity, slab sizes anti-correlated
with it) through the sized device engines and prints both metrics per
policy.  The committed golden (tests/cachesim/golden/sized_cdn.json) locks
the mini-scale numbers; this suite is the quick/full-scale ledger and
asserts the scenario's claim — the byte-hit-ratio ranking differs from the
object-hit-ratio ranking, and the size-aware gradient policy wins on bytes.

Writes ``benchmarks/results/sized_cdn.json``.
"""

from __future__ import annotations

import os

from repro.cachesim.scenarios import get_scenario, run_scenario

from .common import check_finite, csv_row, save_json

SCALE = "full" if os.environ.get("REPRO_BENCH_SCALE") == "full" else "quick"


def main() -> dict:
    sc = get_scenario("sized_cdn")
    res = run_scenario("sized_cdn", scale=SCALE)
    out = res.to_json()
    if not out["skipped"]:  # check_finite rejects empty lists
        del out["skipped"]
    out["byte_capacity"] = sc.byte_capacity(SCALE)

    pols = [k for k in res.rows if k != "OPT(static)"]
    for name in pols:
        row = res.rows[name]
        csv_row(
            f"sized_cdn/{name}",
            row.get("us_per_request", 0.0),
            f"hit_ratio={row['hit_ratio']:.4f} "
            f"byte_hit_ratio={row['byte_hit_ratio']:.4f}",
        )
    opt = res.rows["OPT(static)"]
    print(
        f"OPT(static): hit_ratio={opt['hit_ratio']:.4f} "
        f"byte_hit_ratio={opt['byte_hit_ratio']:.4f}"
    )

    by_obj = sorted(pols, key=lambda k: -res.rows[k]["hit_ratio"])
    by_byte = sorted(pols, key=lambda k: -res.rows[k]["byte_hit_ratio"])
    print(f"ranking by object hits: {by_obj}")
    print(f"ranking by byte hits:   {by_byte}")
    # the scenario's claim, at benchmark scale
    assert by_obj != by_byte, (by_obj, by_byte)
    assert by_byte[0].startswith("OGB_sized"), by_byte

    out["ranking_by_hit_ratio"] = by_obj
    out["ranking_by_byte_hit_ratio"] = by_byte
    check_finite(out)
    save_json("sized_cdn", out)
    return out


if __name__ == "__main__":
    main()
